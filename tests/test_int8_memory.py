"""Int8 quantized memory rows (``mem_dtype="int8"``): the shared per-row
symmetric quantizer, in-kernel dequant fused reads (forward + STE grads,
exact and candidate modes, error bounded by the per-row scale), the
quantized fused write, SAM-cell BPTT parity and bit-exact rollback, the LM
memory layer, SDNC dtype handling, checkpoint mem-dtype migration and
cross-mesh re-layout, SessionStore bit-exact evict/restore, and the
structural no-extra-kernel-launches guard.

The forced-8-device mesh lane for int8 (sharded parity + mesh session
round-trip) lives in tests/test_mesh_parity.py with the rest of the mesh
suite, driven by tests/test_sharding_optim.py.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import addressing as addr
from repro.core import dnc as dnc_lib
from repro.core import sam as sam_lib
from repro.core import unroll as unroll_lib
from repro.core.cell import SAMCell, SDNCCell
from repro.core.quant import SCALE_DTYPE, dequantize_rows, quantize_rows
from repro.core.types import ControllerConfig, MemoryConfig
from repro.kernels import ops
from repro.kernels.introspect import count_primitives
from repro.launch.engine.sessions import SessionStore
from repro.models import sam_layer
from repro.models.config import MemoryLayerConfig, ModelConfig

BACKENDS = ["ref", "pallas-interpret"]


# --------------------------------------------------------------------------
# Quantizer invariants (core/quant.py)
# --------------------------------------------------------------------------

def _rows(key, shape=(3, 5, 16), zero_row=True):
    x = np.array(jax.random.normal(key, shape), np.float32)
    if zero_row:
        x[..., 0, :] = 0.0            # exercise the exact-zero invariant
    return jnp.asarray(x)


def test_quantize_error_bound(rng_key):
    x = _rows(rng_key) * 7.3
    q, s = quantize_rows(x)
    assert q.dtype == jnp.int8 and s.dtype == SCALE_DTYPE
    err = np.abs(np.asarray(dequantize_rows(q, s)) - np.asarray(x))
    bound = np.asarray(s)[..., None] / 2 + 1e-7
    assert (err <= bound).all()
    # scale is exactly max|row| / 127
    np.testing.assert_array_equal(
        np.asarray(s), np.max(np.abs(np.asarray(x)), -1) / np.float32(127))


def test_quantize_roundtrip_is_identity(rng_key):
    """`quantize_rows` always emits max|q| = 127 (or an all-zero row), so
    requantizing its own dequantized output is bit-identical — the
    property that keeps non-owning shards and zero-add scatter passes
    from drifting the stored bits."""
    q, s = quantize_rows(_rows(rng_key))
    q2, s2 = quantize_rows(dequantize_rows(q, s))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


def test_exact_zero_invariant(rng_key):
    q, s = quantize_rows(jnp.zeros((2, 4, 8)))
    np.testing.assert_array_equal(np.asarray(s), 0.0)
    np.testing.assert_array_equal(np.asarray(dequantize_rows(q, s)), 0.0)


def test_ckpt_numpy_twin_matches_jax_quantizer(rng_key):
    x = np.array(jax.random.normal(rng_key, (6, 16)), np.float32)
    x[2] = 0.0
    qn, sn = ckpt._np_quantize_rows(x)
    qj, sj = quantize_rows(jnp.asarray(x))
    np.testing.assert_array_equal(qn, np.asarray(qj))
    np.testing.assert_array_equal(sn, np.asarray(sj))
    np.testing.assert_array_equal(ckpt._np_dequantize_rows(qn, sn),
                                  np.asarray(dequantize_rows(qj, sj)))


# --------------------------------------------------------------------------
# Fused read: in-kernel dequant parity (forward + STE gradients)
# --------------------------------------------------------------------------

def _read_case(key, B=2, H=3, N=64, W=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, W))
    memf = jax.random.normal(ks[1], (B, N, W)) * 3.0
    beta = jax.random.uniform(ks[2], (B, H), minval=1.0, maxval=3.0)
    mem8, scale = quantize_rows(memf)
    return q, memf, mem8, scale, beta


@pytest.mark.parametrize("backend", BACKENDS)
def test_int8_exact_read_matches_dequantized_f32(backend):
    """The in-kernel dequant read equals the f32 read of the dequantized
    buffer (cosine ranking is invariant to the positive per-row scale, so
    selection is identical; the tail sees identical values)."""
    q, _, mem8, scale, beta = _read_case(jax.random.PRNGKey(0))
    r8, w8, i8 = ops.fused_read(q, mem8, beta, 4, backend=backend,
                                mem_scale=scale)
    deq = dequantize_rows(mem8, scale)
    rf, wf, if_ = ops.fused_read(q, deq, beta, 4, backend=backend)
    np.testing.assert_array_equal(np.asarray(i8), np.asarray(if_))
    np.testing.assert_allclose(np.asarray(w8), np.asarray(wf), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r8), np.asarray(rf), atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_int8_read_within_scale_bound_of_f32(backend):
    """Row-norm-scaled parity with the unquantized read: the read word is
    a convex combination of rows each within scale_i/2 per element, so
    the error is bounded by the largest per-row scale (= max|row|/127)."""
    q, memf, mem8, scale, beta = _read_case(jax.random.PRNGKey(1))
    r8, _, _ = ops.fused_read(q, mem8, beta, 4, backend=backend,
                              mem_scale=scale)
    rf, _, _ = ops.fused_read(q, memf, beta, 4, backend=backend)
    tol = float(np.max(np.asarray(scale)))
    np.testing.assert_allclose(np.asarray(r8), np.asarray(rf), atol=tol)


def test_int8_read_grads_match_ref_oracle():
    """STE gradients: the Pallas custom VJP (backward re-runs the jnp
    oracle) matches plain autodiff through the ref backend for every
    float input — q, beta, and the f32 mem_scale (the magnitude channel
    the int8 memory trains through)."""
    q, _, mem8, scale, beta = _read_case(jax.random.PRNGKey(2))
    tr = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 16))

    def loss(q, beta, scale, backend):
        r, w, _ = ops.fused_read(q, mem8, beta, 4, backend=backend,
                                 mem_scale=scale)
        return jnp.sum(r * tr) + jnp.sum(w ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, beta, scale, "ref")
    g_pal = jax.grad(loss, argnums=(0, 1, 2))(q, beta, scale,
                                              "pallas-interpret")
    for a, b in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert all(np.isfinite(np.asarray(g)).all() for g in g_ref)
    assert float(jnp.abs(g_ref[2]).sum()) > 0    # scale channel is live


@pytest.mark.parametrize("backend", BACKENDS)
def test_int8_candidate_read_with_duplicates(backend):
    """LSH-candidate mode: duplicate and invalid (-1) candidates under
    int8 storage behave exactly like the f32 read of the dequantized
    buffer — duplicates deduped, invalid slots zero-weighted."""
    q, _, mem8, scale, beta = _read_case(jax.random.PRNGKey(4))
    cand = jnp.array([[[3, 3, 7, -1, 9, 12], [5, 5, 5, 2, -1, 1],
                       [0, 1, 2, 3, 4, 5]]] * 2, jnp.int32)
    r8, w8, i8 = ops.fused_read(q, mem8, beta, 4, cand_idx=cand,
                                backend=backend, mem_scale=scale)
    deq = dequantize_rows(mem8, scale)
    rf, wf, if_ = ops.fused_read(q, deq, beta, 4, cand_idx=cand,
                                 backend=backend)
    np.testing.assert_array_equal(np.asarray(i8), np.asarray(if_))
    np.testing.assert_allclose(np.asarray(w8), np.asarray(wf), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r8), np.asarray(rf), atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_zero_memory_reads_exact_zero(backend):
    """Exact-zero invariant end to end: all-zero rows carry scale 0, the
    fused read returns exactly 0.0, and no gradient flows into the scale
    (the dequantized rows are identically zero)."""
    B, H, N, W = 2, 2, 32, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, W))
    mem8, scale = quantize_rows(jnp.zeros((B, N, W)))
    beta = jnp.ones((B, H))

    def loss(q, scale):
        r, _, _ = ops.fused_read(q, mem8, beta, 4, backend=backend,
                                 mem_scale=scale)
        return jnp.abs(r).sum()

    val, (gq, gs) = jax.value_and_grad(loss, argnums=(0, 1))(q, scale)
    assert float(val) == 0.0
    np.testing.assert_array_equal(np.asarray(gs), 0.0)


# --------------------------------------------------------------------------
# Structural guard: in-kernel dequant stages no extra kernel launches
# --------------------------------------------------------------------------

def test_int8_read_is_still_one_dispatch():
    q, _, mem8, scale, beta = _read_case(jax.random.PRNGKey(5))
    deq = dequantize_rows(mem8, scale)
    c8 = count_primitives(
        lambda: ops.fused_read(q, mem8, beta, 4, backend="pallas",
                               mem_scale=scale))
    cf = count_primitives(
        lambda: ops.fused_read(q, deq, beta, 4, backend="pallas"))
    assert c8["pallas_call"] == cf["pallas_call"] == 1
    assert c8["top_k"] == c8["sort"] == 0
    cand = jnp.zeros((2, 3, 6), jnp.int32)
    c8c = count_primitives(
        lambda: ops.fused_read(q, mem8, beta, 4, cand_idx=cand,
                               backend="pallas", mem_scale=scale))
    assert c8c["pallas_call"] == 1


def test_int8_write_is_still_one_dispatch():
    B, N, W, H, K = 2, 64, 16, 2, 2
    J = H * (K + 1)
    memf = jax.random.normal(jax.random.PRNGKey(0), (B, N + 1, W))
    mem8, scale = quantize_rows(memf)
    la = jnp.zeros((B, N + 1), jnp.int32)
    widx = jax.random.randint(jax.random.PRNGKey(1), (B, J), 0, N)
    lra = widx.reshape(B, H, K + 1)[..., -1]
    ww = jax.random.uniform(jax.random.PRNGKey(2), (B, J))
    a = jax.random.normal(jax.random.PRNGKey(3), (B, H, W))

    def write(mem_scale):
        return ops.sparse_write_update(mem8, la, widx, ww, a, lra,
                                       jnp.int32(1), delta=0.005,
                                       backend="pallas", scratch_row=N,
                                       mem_scale=mem_scale)

    c8 = count_primitives(write, scale)
    cf = count_primitives(
        lambda: ops.sparse_write_update(memf, la, widx, ww, a, lra,
                                        jnp.int32(1), delta=0.005,
                                        backend="pallas", scratch_row=N))
    assert c8["pallas_call"] == cf["pallas_call"] == 1


# --------------------------------------------------------------------------
# SAM cell: BPTT parity and bit-exact rollback
# --------------------------------------------------------------------------

N, W, H, K, B, T, D = 32, 16, 2, 2, 2, 4, 6
CTL = ControllerConfig(D, 16, D)


def _sam_cell(ann, backend):
    mem = MemoryConfig(num_slots=N, word_size=W, num_heads=H, k=K, ann=ann,
                       mem_dtype="int8", backend=backend,
                       lsh_tables=2, lsh_bits=3, lsh_bucket_size=8)
    return SAMCell(sam_lib.SAMConfig(mem, CTL))


def _unroll_loss(cell, params, state, mode, chunk=None):
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, D))
    st, ys = unroll_lib.unroll(cell, params, state, xs, mode=mode,
                               chunk=chunk)
    return (ys ** 2).sum(), st


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("ann", ["exact", "lsh"])
def test_sam_int8_sparse_bptt_matches_naive(ann, backend):
    cell = _sam_cell(ann, backend)
    params = cell.init_params(jax.random.PRNGKey(0))
    state = cell.init_state(B)
    assert state.memory.dtype == jnp.int8
    assert state.mem_scale.dtype == SCALE_DTYPE

    def run(mode, chunk=None):
        return jax.value_and_grad(
            lambda p: _unroll_loss(cell, p, cell.init_state(B), mode,
                                   chunk)[0])(params)

    ln, gn = run("naive")
    for mode, chunk in [("sparse", None), ("chunked", 2)]:
        ls, gs = run(mode, chunk)
        np.testing.assert_allclose(float(ln), float(ls), atol=1e-5)
        for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gs)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_sam_int8_rollback_bit_exact(backend):
    """§3.4 rollback under int8 storage: old_rows record the raw int8
    bits and old_scale the pre-write scales, so rolling back restores the
    logical rows *bit-exactly* — integer equality, not a tolerance."""
    cell = _sam_cell("exact", backend)
    params = cell.init_params(jax.random.PRNGKey(0))
    s0 = cell.init_state(B)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    # Two steps so the memory is non-trivial before the rolled-back step.
    s1, _, _ = sam_lib.sam_step(params, cell.cfg, s0, x,
                                collect_deltas=True)
    s2, _, d2 = sam_lib.sam_step(params, cell.cfg, s1, x * 0.5,
                                 collect_deltas=True)
    assert d2.old_rows.dtype == jnp.int8
    assert d2.old_scale is not None
    back = cell.rollback(s2, cell.residual_state(s1), d2)
    np.testing.assert_array_equal(np.asarray(back.memory[:, :N]),
                                  np.asarray(s1.memory[:, :N]))
    np.testing.assert_array_equal(np.asarray(back.mem_scale[:, :N]),
                                  np.asarray(s1.mem_scale[:, :N]))


# --------------------------------------------------------------------------
# LM memory layer (models/sam_layer.py)
# --------------------------------------------------------------------------

def _lm_cfg(mem_dtype, backend="ref", unroll_mode="sparse"):
    return ModelConfig(
        name="t", num_layers=2, d_model=16, num_heads=2, num_kv_heads=2,
        d_ff=32, vocab_size=64,
        memory=MemoryLayerConfig(num_slots=N, word_size=8, num_heads=2,
                                 k=2, segment=4, backend=backend,
                                 mem_dtype=mem_dtype,
                                 unroll_mode=unroll_mode))


def test_lm_memory_state_is_first_class_mem_dtype():
    """Satellite: `mem_dtype` is read directly off the config (no getattr
    fallback) and honored for every storage dtype."""
    for dt, want in [("float32", jnp.float32), ("bfloat16", jnp.bfloat16),
                     ("int8", jnp.int8)]:
        st = sam_layer.init_memory_state(_lm_cfg(dt), B)
        assert st.memory.dtype == want, dt
    st = sam_layer.init_memory_state(_lm_cfg("int8"), B)
    assert st.mem_scale is not None and st.mem_scale.dtype == SCALE_DTYPE
    shapes = sam_layer.memory_state_shapes(_lm_cfg("int8"), B)
    assert shapes["mem_scale"] == shapes["last_access"]
    assert "mem_scale" not in sam_layer.memory_state_shapes(
        _lm_cfg("float32"), B)


@pytest.mark.parametrize("backend", BACKENDS)
def test_lm_int8_sparse_unroll_matches_naive(backend):
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 16, 16))
    cell = sam_layer.LMMemoryCell(_lm_cfg("int8", backend))
    p = cell.init_params(jax.random.PRNGKey(0))

    def loss(p, mode):
        c = _lm_cfg("int8", backend, mode)
        y, _ = sam_layer.memory_layer_seq(
            p, c, x, sam_layer.init_memory_state(c, B))
        return (y ** 2).mean()

    ln, gn = jax.value_and_grad(lambda p: loss(p, "naive"))(p)
    ls, gs = jax.value_and_grad(lambda p: loss(p, "sparse"))(p)
    np.testing.assert_allclose(float(ln), float(ls), atol=1e-5)
    for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# --------------------------------------------------------------------------
# SDNC: first-class mem_dtype (satellite), int8 explicitly rejected
# --------------------------------------------------------------------------

def _sdnc_cfg(mem_dtype):
    mem = MemoryConfig(num_slots=N, word_size=W, num_heads=H, k=K,
                       mem_dtype=mem_dtype)
    return dnc_lib.DNCConfig(mem, CTL, k_l=4, sparse=True)


def test_sdnc_honors_bf16_mem_dtype(rng_key):
    cell = SDNCCell(_sdnc_cfg("bfloat16"))
    params = cell.init_params(rng_key)
    state = cell.init_state(B)
    assert state.memory.dtype == jnp.bfloat16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    st, y = cell.step(params, state, x)
    assert st.memory.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(y)).all()


def test_sdnc_rejects_int8():
    with pytest.raises(ValueError, match="int8"):
        SDNCCell(_sdnc_cfg("int8")).init_state(B)


# --------------------------------------------------------------------------
# Checkpoint: mem-dtype migration + cross-mesh re-layout
# --------------------------------------------------------------------------

def _filled_lm_state(cfg, key):
    st = sam_layer.init_memory_state(cfg, B)
    memf = jax.random.normal(key, st.memory.shape)
    if cfg.memory.mem_dtype == "int8":
        q, s = quantize_rows(memf)
        return st._replace(memory=q, mem_scale=s)
    return st._replace(memory=memf.astype(st.memory.dtype))


def test_ckpt_float_to_int8_migration(rng_key, tmp_path):
    st32 = _filled_lm_state(_lm_cfg("float32"), rng_key)
    tmpl8 = sam_layer.init_memory_state(_lm_cfg("int8"), B)
    ckpt.save_checkpoint(str(tmp_path), 0, st32._asdict(), mem_layout=(N, 1))
    r8, _ = ckpt.restore_checkpoint(str(tmp_path), tmpl8._asdict(),
                                    expect_num_slots=N)
    q, s = quantize_rows(st32.memory)
    np.testing.assert_array_equal(np.asarray(r8["memory"]), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(r8["mem_scale"]),
                                  np.asarray(s))


def test_ckpt_int8_round_trips_through_float(rng_key, tmp_path):
    """f32 → int8 → f32 → int8: the second quantization is the identity
    (round-trip property), so the int8 bits and scales survive a detour
    through a float checkpoint unchanged."""
    st8 = _filled_lm_state(_lm_cfg("int8"), rng_key)
    tmpl32 = sam_layer.init_memory_state(_lm_cfg("float32"), B)
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    ckpt.save_checkpoint(d1, 0, st8._asdict(), mem_layout=(N, 1))
    r32, _ = ckpt.restore_checkpoint(d1, tmpl32._asdict(),
                                     expect_num_slots=N)
    assert r32["memory"].dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(r32["memory"]),
        np.asarray(dequantize_rows(st8.memory, st8.mem_scale)))
    ckpt.save_checkpoint(d2, 0, r32, mem_layout=(N, 1))
    tmpl8 = sam_layer.init_memory_state(_lm_cfg("int8"), B)
    back, _ = ckpt.restore_checkpoint(d2, tmpl8._asdict(),
                                      expect_num_slots=N)
    np.testing.assert_array_equal(np.asarray(back["memory"]),
                                  np.asarray(st8.memory))
    np.testing.assert_array_equal(np.asarray(back["mem_scale"]),
                                  np.asarray(st8.mem_scale))


def test_ckpt_int8_cross_mesh_relayout(rng_key, tmp_path):
    """An int8 checkpoint saved in the canonical layout restores into an
    8-shard slot layout (and back), the int8 bits and f32 scales moving
    together — host-side np_relayout, no devices needed."""
    st8 = _filled_lm_state(_lm_cfg("int8"), rng_key)
    ckpt.save_checkpoint(str(tmp_path), 0, st8._asdict(), mem_layout=(N, 1))
    tmpl = {k: jax.ShapeDtypeStruct(
        (v.shape[0], N + 8) + v.shape[2:], v.dtype)
        if k in ("memory", "last_access", "mem_scale") else v
        for k, v in st8._asdict().items()}
    r, _ = ckpt.restore_checkpoint(str(tmp_path), tmpl, expect_num_slots=N)
    from repro.distributed.mem_shard import np_relayout
    for k in ("memory", "mem_scale", "last_access"):
        got_back = np_relayout(np.asarray(r[k]), N, 8, 1)[:, :N]
        np.testing.assert_array_equal(got_back,
                                      np.asarray(st8._asdict()[k])[:, :N])


# --------------------------------------------------------------------------
# SessionStore: bit-exact int8 evict/restore (single-device lane)
# --------------------------------------------------------------------------

def test_session_store_int8_bit_exact(rng_key, tmp_path):
    st = _filled_lm_state(_lm_cfg("int8"), rng_key)
    store = SessionStore(num_slots=N, capacity=1, spill_dir=str(tmp_path))
    store.put("u1", st._asdict())
    store.put("u2", st._asdict())          # evicts u1 to disk
    assert store.spills == 1
    for user in ("u1", "u2"):              # u1 spilled, u2 hot
        back = store.take(user)
        for k, v in st._asdict().items():
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(v), err_msg=k)
        assert back["memory"].dtype == np.int8


def test_decode_sessions_int8_bit_exact_resume(rng_key, tmp_path):
    """Serving-shaped end-to-end: decode a few memory steps, evict the
    session through the store (spill + restore), continue — the
    continuation matches the uninterrupted run bit-exactly on the int8
    memory bits, scales, and usage table."""
    cfg = _lm_cfg("int8")
    cell = sam_layer.LMMemoryCell(cfg)
    p = cell.init_params(rng_key)
    xs = jax.random.normal(jax.random.PRNGKey(1), (6, B, 16))

    def advance(state, lo, hi):
        for t in range(lo, hi):
            state, _ = sam_layer.memory_access(p, cfg, xs[t], state)
        return state

    full = advance(sam_layer.init_memory_state(cfg, B), 0, 6)
    half = advance(sam_layer.init_memory_state(cfg, B), 0, 3)
    store = SessionStore(num_slots=cfg.memory.num_slots, capacity=1,
                         spill_dir=str(tmp_path))
    store.put("u", jax.tree.map(np.asarray, half._asdict()))
    store.put("other", {"x": np.zeros(3)})       # force the spill of "u"
    assert store.spills == 1
    back = sam_layer.MemoryState(**{
        k: None if v is None else jnp.asarray(v)
        for k, v in store.take("u").items()})
    resumed = advance(back, 3, 6)
    np.testing.assert_array_equal(np.asarray(resumed.memory),
                                  np.asarray(full.memory))
    np.testing.assert_array_equal(np.asarray(resumed.mem_scale),
                                  np.asarray(full.mem_scale))
    np.testing.assert_array_equal(np.asarray(resumed.last_access),
                                  np.asarray(full.last_access))


# --------------------------------------------------------------------------
# Compression shares the quantizer (satellite)
# --------------------------------------------------------------------------

def test_compression_uses_shared_quantizer(rng_key):
    from repro.distributed import compression
    g = jax.random.normal(rng_key, (300,)) * 0.01
    q, scale = compression.quantize_int8(g)
    assert q.dtype == jnp.int8 and scale.dtype == SCALE_DTYPE
    back = compression.dequantize_int8(q, scale, g.shape)
    err = np.abs(np.asarray(back) - np.asarray(g))
    assert err.max() <= float(np.asarray(scale).max()) / 2 + 1e-8
    # all-zero gradient blocks round-trip to exact zero (no epsilon floor)
    np.testing.assert_array_equal(
        np.asarray(compression.int8_roundtrip(jnp.zeros((300,)))), 0.0)
