"""Fused sparse-write kernel: parity with the unfused composition and with
the ref oracle, gradients through the custom VJP, and duplicate-index /
erase-overlap edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

BACKENDS = ["ref", "pallas-interpret"]
DELTA = 0.005


def _case(key, B=2, N=32, W=8, H=2, K=3, dup=False, lra_in_writes=False):
    J = H * (K + 1)
    ks = jax.random.split(key, 5)
    mem = jax.random.normal(ks[0], (B, N, W))
    last = jax.random.randint(ks[1], (B, N), -10, 5).astype(jnp.int32)
    widx = jax.random.randint(ks[2], (B, J), 0, N)
    if dup:
        widx = widx.at[:, 1].set(widx[:, 0]).at[:, 2].set(widx[:, 0])
    lra = widx.reshape(B, H, K + 1)[..., -1]
    if lra_in_writes:
        # An LRA row also appears among another head's read rows.
        widx = widx.at[:, 0].set(lra[:, -1])
    ww = jax.random.uniform(ks[3], (B, J), minval=0.0, maxval=0.2)
    ww = ww.at[:, -1].set(1e-4)               # below the δ threshold
    a = jax.random.normal(ks[4], (B, H, W))
    return mem, last, widx, ww, a, lra


def _unfused(mem, last, widx, ww, a, lra, step, delta):
    """The pre-fusion sam_step sequence: scatter-set, scatter-add, usage."""
    B, H, W = a.shape
    J = widx.shape[1]
    kp1 = J // H
    b = jnp.arange(B)[:, None]
    m = mem.at[b, lra].set(jnp.zeros((B, H, W)))
    rows = (ww.reshape(B, H, kp1)[..., None] * a[:, :, None, :]).reshape(B, J, W)
    m = m.at[b, widx].add(rows)
    upd = jnp.where(ww > delta, step, last[b, widx])
    la = last.at[b, widx].max(upd)
    return m, la


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dup,overlap", [(False, False), (True, False),
                                         (False, True), (True, True)])
def test_fused_matches_unfused(backend, dup, overlap):
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(hash((dup, overlap)) % 997),
                                        dup=dup, lra_in_writes=overlap)
    step = jnp.int32(9)
    m1, l1 = ops.sparse_write_update(mem, last, widx, ww, a, lra, step,
                                     delta=DELTA, backend=backend)
    m2, l2 = _unfused(mem, last, widx, ww, a, lra, step, DELTA)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
    assert np.array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_usage_respects_delta(backend):
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(3))
    step = jnp.int32(50)
    _, la = ops.sparse_write_update(mem, last, widx, ww, a, lra, step,
                                    delta=DELTA, backend=backend)
    la, last_np, widx_np, ww_np = (np.asarray(la), np.asarray(last),
                                   np.asarray(widx), np.asarray(ww))
    B, J = widx_np.shape
    for b in range(B):
        stamped = {int(widx_np[b, j]) for j in range(J) if ww_np[b, j] > DELTA}
        for i in range(la.shape[1]):
            if i in stamped:
                assert la[b, i] == 50
            else:
                assert la[b, i] == last_np[b, i]


def test_fused_gradients_match_ref():
    """The closed-form custom VJP of the Pallas path must agree with XLA's
    autodiff through the ref composition (mem, write_w and a cotangents)."""
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(7), dup=True,
                                        lra_in_writes=True)
    step = jnp.int32(4)
    tgt = jax.random.normal(jax.random.PRNGKey(8), mem.shape)

    def loss(backend):
        def f(args):
            m, w_, a_ = args
            m2, _ = ops.sparse_write_update(m, last, widx, w_, a_, lra, step,
                                            delta=DELTA, backend=backend)
            return (m2 * tgt).sum() + (m2 ** 2).sum()
        return f

    g_ref = jax.grad(loss("ref"))((mem, ww, a))
    g_pal = jax.grad(loss("pallas-interpret"))((mem, ww, a))
    for gr, gp in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(gr), np.asarray(gp), atol=1e-5)


def test_scatter_gradients_match_ref():
    """Pallas scatter_rows custom VJP vs XLA autodiff of the jnp reference,
    for both modes (unique indices; the documented duplicate contract for
    'set' is last-wins, checked in test_kernels)."""
    B, N, W, J = 2, 16, 8, 5
    mem = jax.random.normal(jax.random.PRNGKey(0), (B, N, W))
    rows = jax.random.normal(jax.random.PRNGKey(1), (B, J, W))
    idx = jnp.stack([jax.random.permutation(jax.random.PRNGKey(2 + b),
                                            N)[:J] for b in range(B)])
    tgt = jax.random.normal(jax.random.PRNGKey(3), (B, N, W))
    for mode in ("add", "set"):
        def f(args, backend):
            m, r = args
            out = ops.scatter_rows(m, idx, r, mode, backend=backend)
            return (out * tgt).sum()
        g_ref = jax.grad(lambda ar: f(ar, "ref"))((mem, rows))
        g_pal = jax.grad(lambda ar: f(ar, "pallas-interpret"))((mem, rows))
        for gr, gp in zip(g_ref, g_pal):
            np.testing.assert_allclose(np.asarray(gr), np.asarray(gp),
                                       atol=1e-5, err_msg=mode)


def test_ref_oracle_is_exposed():
    """ops with backend='ref' must hit ref.sparse_write_update_ref exactly."""
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(11))
    step = jnp.int32(2)
    m1, l1 = ops.sparse_write_update(mem, last, widx, ww, a, lra, step,
                                     delta=DELTA, backend="ref")
    m2, l2 = ref.sparse_write_update_ref(mem, last, widx, ww, a, lra, step,
                                         DELTA)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    assert np.array_equal(np.asarray(l1), np.asarray(l2))


# --------------------------- per-lane step ---------------------------------

def _step_shapes(backend, B):
    """The step layouts each path accepts: the oracle broadcasts () and
    (B, 1) (the engine's per-lane counter layout); the Pallas wrapper also
    normalizes a flat (B,)."""
    col = jnp.arange(B, dtype=jnp.int32) * 7 + 3
    shapes = [col[:, None]]
    if backend != "ref":
        shapes.append(col)
    return col, shapes


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dup,overlap", [(False, False), (True, True)])
def test_per_lane_step_matches_per_row_scalar(backend, dup, overlap):
    """A (B, 1) per-lane step (the serving engine's session counters) must
    stamp row b's usage exactly as a scalar-step call with step[b] would —
    lane independence, the engine's determinism contract."""
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(21), dup=dup,
                                        lra_in_writes=overlap)
    B = mem.shape[0]
    col, shapes = _step_shapes(backend, B)
    want_m, want_l = [], []
    for b in range(B):
        sl = slice(b, b + 1)
        m, l = ops.sparse_write_update(mem[sl], last[sl], widx[sl], ww[sl],
                                       a[sl], lra[sl], jnp.int32(col[b]),
                                       delta=DELTA, backend=backend)
        want_m.append(np.asarray(m))
        want_l.append(np.asarray(l))
    for step in shapes:
        m1, l1 = ops.sparse_write_update(mem, last, widx, ww, a, lra, step,
                                         delta=DELTA, backend=backend)
        np.testing.assert_allclose(np.asarray(m1), np.concatenate(want_m),
                                   atol=1e-6, err_msg=str(step.shape))
        assert np.array_equal(np.asarray(l1), np.concatenate(want_l))


def test_per_lane_step_parity_across_backends():
    """Pallas vs oracle with the (B, 1) step: forward bit-level usage
    agreement and gradient agreement through the custom VJP."""
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(22), dup=True,
                                        lra_in_writes=True)
    B = mem.shape[0]
    step = (jnp.arange(B, dtype=jnp.int32) * 5 + 2)[:, None]
    m_r, l_r = ops.sparse_write_update(mem, last, widx, ww, a, lra, step,
                                       delta=DELTA, backend="ref")
    m_p, l_p = ops.sparse_write_update(mem, last, widx, ww, a, lra, step,
                                       delta=DELTA,
                                       backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_r), atol=1e-5)
    assert np.array_equal(np.asarray(l_p), np.asarray(l_r))

    tgt = jax.random.normal(jax.random.PRNGKey(23), mem.shape)

    def loss(backend):
        def f(args):
            m, w_, a_ = args
            m2, _ = ops.sparse_write_update(m, last, widx, w_, a_, lra,
                                            step, delta=DELTA,
                                            backend=backend)
            return (m2 * tgt).sum() + (m2 ** 2).sum()
        return f

    g_ref = jax.grad(loss("ref"))((mem, ww, a))
    g_pal = jax.grad(loss("pallas-interpret"))((mem, ww, a))
    for gr, gp in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(gr), np.asarray(gp), atol=1e-5)


def test_per_lane_step_rejects_wrong_length():
    mem, last, widx, ww, a, lra = _case(jax.random.PRNGKey(24))
    bad = jnp.arange(mem.shape[0] + 1, dtype=jnp.int32)
    with pytest.raises(ValueError, match="per-lane step"):
        ops.sparse_write_update(mem, last, widx, ww, a, lra, bad,
                                delta=DELTA, backend="pallas-interpret")
