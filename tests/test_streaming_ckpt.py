"""Streaming trainer + segment-boundary checkpoint state: the train-loop
state (curriculum level + chunk cursor) round-trips through ckpt.py, a job
killed mid-episode resumes at the exact chunk cursor with identical
results, and legacy (params/opt-only) checkpoints load unchanged via
`restore_checkpoint(fill_missing=True)`."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.core.training import (ModelSpec, TrainLoopState, init_loop_state,
                                 train_task_streaming)
from repro.core.types import ControllerConfig, MemoryConfig
from repro.data.curriculum import Curriculum

MEM = MemoryConfig(num_slots=16, word_size=8, num_heads=1, k=2)
CTL = ControllerConfig(input_size=10, hidden_size=16, output_size=8)


def spec(**kw):
    return ModelSpec("sam", MEM, CTL, **kw)


def test_loop_state_roundtrips(tmp_path):
    loop = init_loop_state(8)._replace(episode=jnp.asarray(3, jnp.int32),
                                       cursor=jnp.asarray(5, jnp.int32),
                                       streak=jnp.asarray(2, jnp.int32),
                                       err_sum=jnp.asarray(1.5, jnp.float32),
                                       err_cnt=jnp.asarray(4, jnp.int32))
    tree = {"loop": loop, "params": {"w": jnp.ones((3,))}}
    save_checkpoint(str(tmp_path), 11, tree)
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 11
    assert int(restored["loop"].cursor) == 5
    assert int(restored["loop"].level) == 8
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_legacy_checkpoint_loads_unchanged(tmp_path):
    """A params/opt-only tree (saved before the loop state rode along)
    restores into the extended template: saved leaves bit-exact, missing
    carry/loop leaves fall back to the template values."""
    params = {"w": jnp.arange(4.0)}
    opt = {"ms": jnp.ones((4,))}
    save_checkpoint(str(tmp_path), 2, {"params": params, "opt": opt})

    template = {"params": jnp.zeros((4,)) * 0, "opt": {"ms": jnp.zeros((4,))},
                "carry": jnp.zeros((2, 2)), "loop": init_loop_state(4)}
    template["params"] = {"w": jnp.zeros((4,))}
    restored, step = restore_checkpoint(str(tmp_path), template,
                                        fill_missing=True)
    assert step == 2
    assert np.array_equal(np.asarray(restored["params"]["w"]),
                          np.arange(4.0))
    assert np.array_equal(np.asarray(restored["opt"]["ms"]), np.ones((4,)))
    assert np.array_equal(np.asarray(restored["carry"]), np.zeros((2, 2)))
    assert int(restored["loop"].episode) == 0
    assert int(restored["loop"].level) == 4


def test_fill_missing_rejects_unknown_leaves(tmp_path):
    """fill_missing only tolerates a leaf *subset* — a checkpoint leaf with
    no template counterpart (e.g. a renamed field) must raise."""
    save_checkpoint(str(tmp_path), 1, {"params": {"w": jnp.ones((2,))},
                                       "extra": jnp.zeros((1,))})
    with pytest.raises(ValueError, match="no counterpart"):
        restore_checkpoint(str(tmp_path), {"params": {"w": jnp.zeros((2,))}},
                           fill_missing=True)


def test_strict_restore_still_rejects_structure_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"params": {"w": jnp.ones((2,))}})
    with pytest.raises(AssertionError, match="structure"):
        restore_checkpoint(str(tmp_path), {"params": {"w": jnp.zeros((2,))},
                                           "loop": init_loop_state(2)})


def test_mid_episode_resume_matches_uninterrupted(tmp_path):
    """Kill the streaming trainer mid-episode, resume from the checkpoint,
    and get the same parameters as an uninterrupted run — the chunk cursor
    restores and no data is replayed or skipped (episode data regenerates
    deterministically from (seed, episode))."""
    kw = dict(episodes=2, chunk=4, batch=2, level=3, max_level=4, bits=8,
              lr=1e-3, seed=0)

    p_ref, h_ref = train_task_streaming(spec(), "copy", **kw)

    ckpt_dir = str(tmp_path / "run")
    p_int, h1 = train_task_streaming(spec(), "copy", ckpt_dir=ckpt_dir,
                                     ckpt_every=1, stop_after_chunks=3, **kw)
    assert len(h1) == 3

    p_res, h2 = train_task_streaming(spec(), "copy", ckpt_dir=ckpt_dir,
                                     ckpt_every=1, **kw)
    # Resumed history continues at the saved cursor (no replay of chunk 0-2).
    assert h2[0]["chunk"] == 3 or h2[0]["episode"] > 0
    assert (len(h1) + len(h2)) == len(h_ref)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_streaming_curriculum_state_restores(tmp_path):
    """The curriculum level/streak live in the checkpointed loop state: a
    resume reconstitutes the Curriculum object."""
    ckpt_dir = str(tmp_path / "run")
    cur = Curriculum(start_level=2, threshold=1e9, patience=1)  # dbl each ep
    kw = dict(episodes=3, chunk=4, batch=2, level=2, max_level=4, bits=8,
              lr=1e-3, seed=0, ckpt_dir=ckpt_dir, ckpt_every=1)
    train_task_streaming(spec(), "copy", curriculum=cur, **kw)
    lvl_end = cur.level
    assert lvl_end > 2            # threshold=inf → doubles every episode

    cur2 = Curriculum(start_level=2, threshold=1e9, patience=1)
    train_task_streaming(spec(), "copy", curriculum=cur2, **kw)
    # Nothing left to train (all episodes consumed) but the level restored.
    assert cur2.level == lvl_end
