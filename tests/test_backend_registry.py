"""Backend registry semantics plus the end-to-end acceptance parity:
`sam_step`/`sam_unroll` on the "pallas-interpret" backend must match the
"ref" backend within 1e-5."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sam as sam_lib
from repro.core.unroll import sam_unroll_sparse_bptt
from repro.core.types import ControllerConfig, MemoryConfig
from repro.kernels import ops, ref, registry


# ------------------------------- registry ---------------------------------

def test_resolve_default_is_ref():
    assert registry.resolve(None).name == "ref"
    assert registry.resolve("ref") is registry.resolve(None)


def test_resolve_env_var(monkeypatch):
    monkeypatch.setenv(registry.ENV_VAR, "pallas-interpret")
    be = registry.resolve(None)
    assert be.name == "pallas-interpret" and be.use_pallas and be.interpret


def test_resolve_passthrough_instance():
    be = registry.get("pallas")
    assert registry.resolve(be) is be
    assert be.use_pallas and not be.interpret


def test_unknown_backend_raises_with_listing():
    with pytest.raises(KeyError, match="nope.*available"):
        registry.resolve("nope")


def test_builtins_cannot_be_silently_replaced():
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.KernelBackend(name="ref"))
    with pytest.raises(ValueError, match="built-in"):
        registry.unregister("pallas")


def test_custom_backend_override_is_dispatched():
    """A registered backend's per-op override wins over flags and oracle —
    the documented extension point (docs/kernels.md)."""
    calls = []

    def my_argmin(last_access):
        calls.append(last_access.shape)
        return ref.usage_argmin_ref(last_access)

    be = registry.register(registry.KernelBackend(
        name="custom-test", overrides={"usage_argmin": my_argmin}))
    try:
        u = jnp.array([[3, 1, 2]], jnp.int32)
        out = ops.usage_argmin(u, backend="custom-test")
        assert int(out[0]) == 1 and calls == [(1, 3)]
        # Ops without an override fall back to the oracle.
        v, i = ops.topk_read(jnp.ones((1, 1, 4)), jnp.ones((1, 8, 4)), 2,
                             backend=be)
        assert i.shape == (1, 1, 2)
    finally:
        registry.unregister("custom-test")


# --------------------------- end-to-end parity ----------------------------

CTL = ControllerConfig(input_size=8, hidden_size=24, output_size=6)


def _cfg(backend, ann="exact"):
    mem = MemoryConfig(num_slots=64, word_size=8, num_heads=2, k=2, ann=ann,
                       lsh_tables=2, lsh_bits=4, lsh_bucket_size=8,
                       backend=backend)
    return sam_lib.SAMConfig(mem, CTL)


def _run(backend, ann, T=4, B=2):
    cfg = _cfg(backend, ann)
    key = jax.random.PRNGKey(0)
    params = sam_lib.init_params(key, cfg)
    state = sam_lib.init_state(B, cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, 8))
    stateT, ys = sam_lib.sam_unroll(params, cfg, state, xs)
    return stateT, ys


@pytest.mark.parametrize("ann", ["exact", "lsh"])
def test_sam_unroll_backend_parity(ann):
    """Acceptance: sam_step/sam_unroll end-to-end on backend
    "pallas-interpret" match "ref" within 1e-5 (exact and LSH modes)."""
    s_ref, y_ref = _run("ref", ann)
    s_pal, y_pal = _run("pallas-interpret", ann)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_pal.memory),
                               np.asarray(s_ref.memory), atol=1e-5)
    assert np.array_equal(np.asarray(s_pal.last_access),
                          np.asarray(s_ref.last_access))
    assert np.array_equal(np.asarray(s_pal.read.indices),
                          np.asarray(s_ref.read.indices))


def test_sam_step_backend_parity_single_step():
    cfg_r, cfg_p = _cfg("ref"), _cfg("pallas-interpret")
    key = jax.random.PRNGKey(2)
    params = sam_lib.init_params(key, cfg_r)
    state = sam_lib.init_state(2, cfg_r)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8))
    s1, y1, d1 = sam_lib.sam_step(params, cfg_r, state, x, collect_deltas=True)
    s2, y2, d2 = sam_lib.sam_step(params, cfg_p, state, x, collect_deltas=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=1e-5)
    assert np.array_equal(np.asarray(d2.write_idx), np.asarray(d1.write_idx))
    np.testing.assert_allclose(np.asarray(d2.old_rows),
                               np.asarray(d1.old_rows), atol=1e-5)


def test_sam_grads_backend_parity():
    """Gradients through the naive unroll agree across backends — exercises
    the custom VJPs of the fused write on the production path."""
    def grads(backend):
        cfg = _cfg(backend)
        key = jax.random.PRNGKey(4)
        params = sam_lib.init_params(key, cfg)
        state = sam_lib.init_state(2, cfg)
        xs = jax.random.normal(jax.random.PRNGKey(5), (3, 2, 8))
        return jax.grad(lambda p: (sam_lib.sam_unroll(p, cfg, state, xs)[1]
                                   ** 2).sum())(params)

    g_ref, g_pal = grads("ref"), grads("pallas-interpret")
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-3), g_ref, g_pal)


def test_sparse_bptt_on_pallas_backend():
    """The rollback BPTT must run and match the naive unroll's gradients on
    the pallas-interpret backend (replay + rollback both dispatch)."""
    cfg = _cfg("pallas-interpret")
    key = jax.random.PRNGKey(6)
    params = sam_lib.init_params(key, cfg)
    state = sam_lib.init_state(2, cfg)
    xs = jax.random.normal(jax.random.PRNGKey(7), (3, 2, 8))

    g1 = jax.grad(lambda p: (sam_lib.sam_unroll(p, cfg, state, xs)[1]
                             ** 2).sum())(params)
    g2 = jax.grad(lambda p: (sam_unroll_sparse_bptt(p, cfg, state, xs)[1]
                             ** 2).sum())(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-3), g1, g2)


def test_memory_config_backend_field_is_static():
    cfg = MemoryConfig(backend="pallas-interpret")
    assert dataclasses.asdict(cfg)["backend"] == "pallas-interpret"
    hash(cfg)   # frozen + hashable, safe as a static jit argument


# -------------- scratch-row layout: ref vs pallas parity sweep --------------
#
# The persistent (B, N+1, W) layout (docs/memory-model.md) must be
# observationally identical across backends — forward, `jax.grad`, and the
# rollback-BPTT restore — including the configurations that exercise the
# silent-fallback paths (block-divisibility, float-dtype `lra_topn`).

SWEEP = [
    # (num_slots, word_size, heads, k, T, B). All configs stay on the
    # kernel path end-to-end: `sam_step` never overrides block_n, so the
    # clamp to min(block_n, N) always divides. The fallback paths are
    # exercised at the ops level below, where block_n can be forced.
    (64, 8, 2, 2, 4, 2),
    (80, 8, 2, 4, 3, 1),
    (48, 16, 4, 2, 3, 2),
]


def _sweep_cfg(backend, shape):
    n, w, h, k, _, _ = shape
    mem = MemoryConfig(num_slots=n, word_size=w, num_heads=h, k=k,
                      backend=backend)
    return sam_lib.SAMConfig(mem, CTL)


@pytest.mark.parametrize("shape", SWEEP,
                         ids=[f"N{s[0]}W{s[1]}H{s[2]}K{s[3]}" for s in SWEEP])
def test_layout_parity_forward_grad_bptt(shape):
    """Forward outputs/state (1e-5), naive-unroll grads, and rollback-BPTT
    grads agree between "ref" and "pallas-interpret" on the padded layout."""
    *_, T, B = shape

    def run(backend):
        cfg = _sweep_cfg(backend, shape)
        params = sam_lib.init_params(jax.random.PRNGKey(0), cfg)
        state = sam_lib.init_state(B, cfg)
        assert state.memory.shape[1] == cfg.memory.num_slots + 1
        xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, 8))
        stateT, ys = sam_lib.sam_unroll(params, cfg, state, xs)
        g = jax.grad(lambda p: (sam_lib.sam_unroll(p, cfg, state, xs)[1]
                                ** 2).sum())(params)
        gb = jax.grad(lambda p: (sam_unroll_sparse_bptt(p, cfg, state, xs)[1]
                                 ** 2).sum())(params)
        return stateT, ys, g, gb

    s_ref, y_ref, g_ref, gb_ref = run("ref")
    s_pal, y_pal, g_pal, gb_pal = run("pallas-interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_pal.memory),
                               np.asarray(s_ref.memory), atol=1e-5)
    assert np.array_equal(np.asarray(s_pal.last_access),
                          np.asarray(s_ref.last_access))
    for ga, gb in ((g_ref, g_pal), (gb_ref, gb_pal)):
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-3), ga, gb)
    # The rollback restore itself: BPTT grads also match the naive unroll.
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-3), g_pal, gb_pal)


@pytest.mark.parametrize("block_n,expect_kernel", [(32, True), (40, False)])
def test_layout_parity_block_divisibility_fallback(block_n, expect_kernel,
                                                   monkeypatch):
    """ops-level sweep on the padded layout: divisibility is checked against
    the *logical* N (so N=64 at block 32 stays on the kernel path despite
    the 65-row buffer), and a non-divisible block silently falls back to
    the sliced reference with identical results. The execution path is
    asserted by spying on the dispatch targets — results alone can't
    distinguish them (they must agree by contract)."""
    N, W, H, K = 64, 8, 2, 4
    calls = {"kernel": 0, "oracle": 0}
    real_kernel, real_oracle = ops.topk_read_pallas, ops.ref.topk_read_ref

    def spy_kernel(*a, **kw):
        calls["kernel"] += 1
        return real_kernel(*a, **kw)

    def spy_oracle(*a, **kw):
        calls["oracle"] += 1
        return real_oracle(*a, **kw)

    monkeypatch.setattr(ops, "topk_read_pallas", spy_kernel)
    monkeypatch.setattr(ops.ref, "topk_read_ref", spy_oracle)

    mem = jax.random.normal(jax.random.PRNGKey(0), (1, N + 1, W))
    mem = mem.at[:, N].set(1e3)          # garbage scratch: must never win
    q = jax.random.normal(jax.random.PRNGKey(1), (1, H, W))
    v_ref, i_ref = ops.topk_read(q, mem, K, backend="ref", valid_n=N)
    assert calls == {"kernel": 0, "oracle": 1}
    v_pal, i_pal = ops.topk_read(q, mem, K, backend="pallas-interpret",
                                 block_n=block_n, valid_n=N)
    assert calls["kernel"] == (1 if expect_kernel else 0)
    assert calls["oracle"] == (1 if expect_kernel else 2)
    assert np.array_equal(np.sort(np.asarray(i_pal)), np.sort(np.asarray(i_ref)))
    np.testing.assert_allclose(np.sort(np.asarray(v_pal)),
                               np.sort(np.asarray(v_ref)), atol=1e-5)
    assert int(np.asarray(i_pal).max()) < N


def test_layout_parity_float_dtype_fallback():
    """Float usage tables (DAM's U^(1)) take the reference path for
    `lra_topn` even on a pallas backend — with valid_n the slice happens
    before the oracle, so a float garbage scratch entry can never win."""
    N, H = 48, 4
    la = jax.random.uniform(jax.random.PRNGKey(0), (2, N + 1)) * 10.0
    la = la.at[:, N].set(-1e9)           # would win the argmin if swept
    out_ref = ops.lra_topn(la, H, backend="ref", valid_n=N)
    out_pal = ops.lra_topn(la, H, backend="pallas-interpret", valid_n=N)
    assert np.array_equal(np.asarray(out_ref), np.asarray(out_pal))
    assert int(np.asarray(out_pal).max()) < N


def test_old_signature_override_works_on_padded_layout():
    """A custom backend registered with the pre-scratch-row override
    signatures must keep working now that the padded layout is the default
    state: sweep overrides get the sliced logical view, mutating overrides
    run without `scratch_row` (docs/kernels.md 'Adding a backend')."""
    seen = {}

    def old_topk(q, mem, k, *, block_n=512):
        seen["topk_n"] = mem.shape[1]
        return ref.topk_read_ref(q, mem, k)

    def old_write(mem, last, widx, ww, a, lra, step, *, delta):
        seen["write_rows"] = mem.shape[1]
        return ref.sparse_write_update_ref(mem, last, widx, ww, a, lra,
                                           step, delta)

    registry.register(registry.KernelBackend(
        name="old-sig-test",
        overrides={"topk_read": old_topk, "sparse_write_update": old_write}))
    try:
        cfg = _cfg("old-sig-test")
        params = sam_lib.init_params(jax.random.PRNGKey(0), cfg)
        state = sam_lib.init_state(2, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
        _, y = sam_lib.sam_step(params, cfg, state, x)
        assert bool(jnp.isfinite(y).all())
        N = cfg.memory.num_slots
        assert seen["topk_n"] == N          # sweep saw the sliced view
        assert seen["write_rows"] == N + 1  # mutating op saw the full buffer
        # Parity with the ref backend on the same padded state.
        _, y_ref = sam_lib.sam_step(params, _cfg("ref"), state, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-6)
    finally:
        registry.unregister("old-sig-test")


def test_layout_parity_checkpoint_restore_roundtrip(tmp_path):
    """A padded state saved on one backend restores and continues on the
    other with identical outputs (the layout is backend-independent)."""
    from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
    cfg_r, cfg_p = _cfg("ref"), _cfg("pallas-interpret")
    params = sam_lib.init_params(jax.random.PRNGKey(0), cfg_r)
    state = sam_lib.init_state(2, cfg_r)
    xs = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 8))
    mid, _ = sam_lib.sam_unroll(params, cfg_r, state, xs)
    save_checkpoint(str(tmp_path), 1, mid)
    restored, _ = restore_checkpoint(str(tmp_path), mid)
    x2 = jax.random.normal(jax.random.PRNGKey(2), (2, 8))
    _, y_ref = sam_lib.sam_step(params, cfg_r, restored, x2)
    _, y_pal = sam_lib.sam_step(params, cfg_p, restored, x2)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5)
