"""Backend registry semantics plus the end-to-end acceptance parity:
`sam_step`/`sam_unroll` on the "pallas-interpret" backend must match the
"ref" backend within 1e-5."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sam as sam_lib
from repro.core.bptt import sam_unroll_sparse_bptt
from repro.core.types import ControllerConfig, MemoryConfig
from repro.kernels import ops, ref, registry


# ------------------------------- registry ---------------------------------

def test_resolve_default_is_ref():
    assert registry.resolve(None).name == "ref"
    assert registry.resolve("ref") is registry.resolve(None)


def test_resolve_env_var(monkeypatch):
    monkeypatch.setenv(registry.ENV_VAR, "pallas-interpret")
    be = registry.resolve(None)
    assert be.name == "pallas-interpret" and be.use_pallas and be.interpret


def test_resolve_passthrough_instance():
    be = registry.get("pallas")
    assert registry.resolve(be) is be
    assert be.use_pallas and not be.interpret


def test_unknown_backend_raises_with_listing():
    with pytest.raises(KeyError, match="nope.*available"):
        registry.resolve("nope")


def test_builtins_cannot_be_silently_replaced():
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.KernelBackend(name="ref"))
    with pytest.raises(ValueError, match="built-in"):
        registry.unregister("pallas")


def test_custom_backend_override_is_dispatched():
    """A registered backend's per-op override wins over flags and oracle —
    the documented extension point (docs/kernels.md)."""
    calls = []

    def my_argmin(last_access):
        calls.append(last_access.shape)
        return ref.usage_argmin_ref(last_access)

    be = registry.register(registry.KernelBackend(
        name="custom-test", overrides={"usage_argmin": my_argmin}))
    try:
        u = jnp.array([[3, 1, 2]], jnp.int32)
        out = ops.usage_argmin(u, backend="custom-test")
        assert int(out[0]) == 1 and calls == [(1, 3)]
        # Ops without an override fall back to the oracle.
        v, i = ops.topk_read(jnp.ones((1, 1, 4)), jnp.ones((1, 8, 4)), 2,
                             backend=be)
        assert i.shape == (1, 1, 2)
    finally:
        registry.unregister("custom-test")


# --------------------------- end-to-end parity ----------------------------

CTL = ControllerConfig(input_size=8, hidden_size=24, output_size=6)


def _cfg(backend, ann="exact"):
    mem = MemoryConfig(num_slots=64, word_size=8, num_heads=2, k=2, ann=ann,
                       lsh_tables=2, lsh_bits=4, lsh_bucket_size=8,
                       backend=backend)
    return sam_lib.SAMConfig(mem, CTL)


def _run(backend, ann, T=4, B=2):
    cfg = _cfg(backend, ann)
    key = jax.random.PRNGKey(0)
    params = sam_lib.init_params(key, cfg)
    state = sam_lib.init_state(B, cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (T, B, 8))
    stateT, ys = sam_lib.sam_unroll(params, cfg, state, xs)
    return stateT, ys


@pytest.mark.parametrize("ann", ["exact", "lsh"])
def test_sam_unroll_backend_parity(ann):
    """Acceptance: sam_step/sam_unroll end-to-end on backend
    "pallas-interpret" match "ref" within 1e-5 (exact and LSH modes)."""
    s_ref, y_ref = _run("ref", ann)
    s_pal, y_pal = _run("pallas-interpret", ann)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_pal.memory),
                               np.asarray(s_ref.memory), atol=1e-5)
    assert np.array_equal(np.asarray(s_pal.last_access),
                          np.asarray(s_ref.last_access))
    assert np.array_equal(np.asarray(s_pal.read.indices),
                          np.asarray(s_ref.read.indices))


def test_sam_step_backend_parity_single_step():
    cfg_r, cfg_p = _cfg("ref"), _cfg("pallas-interpret")
    key = jax.random.PRNGKey(2)
    params = sam_lib.init_params(key, cfg_r)
    state = sam_lib.init_state(2, cfg_r)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8))
    s1, y1, d1 = sam_lib.sam_step(params, cfg_r, state, x, collect_deltas=True)
    s2, y2, d2 = sam_lib.sam_step(params, cfg_p, state, x, collect_deltas=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=1e-5)
    assert np.array_equal(np.asarray(d2.write_idx), np.asarray(d1.write_idx))
    np.testing.assert_allclose(np.asarray(d2.old_rows),
                               np.asarray(d1.old_rows), atol=1e-5)


def test_sam_grads_backend_parity():
    """Gradients through the naive unroll agree across backends — exercises
    the custom VJPs of the fused write on the production path."""
    def grads(backend):
        cfg = _cfg(backend)
        key = jax.random.PRNGKey(4)
        params = sam_lib.init_params(key, cfg)
        state = sam_lib.init_state(2, cfg)
        xs = jax.random.normal(jax.random.PRNGKey(5), (3, 2, 8))
        return jax.grad(lambda p: (sam_lib.sam_unroll(p, cfg, state, xs)[1]
                                   ** 2).sum())(params)

    g_ref, g_pal = grads("ref"), grads("pallas-interpret")
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-3), g_ref, g_pal)


def test_sparse_bptt_on_pallas_backend():
    """The rollback BPTT must run and match the naive unroll's gradients on
    the pallas-interpret backend (replay + rollback both dispatch)."""
    cfg = _cfg("pallas-interpret")
    key = jax.random.PRNGKey(6)
    params = sam_lib.init_params(key, cfg)
    state = sam_lib.init_state(2, cfg)
    xs = jax.random.normal(jax.random.PRNGKey(7), (3, 2, 8))

    g1 = jax.grad(lambda p: (sam_lib.sam_unroll(p, cfg, state, xs)[1]
                             ** 2).sum())(params)
    g2 = jax.grad(lambda p: (sam_unroll_sparse_bptt(p, cfg, state, xs)[1]
                             ** 2).sum())(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-4, rtol=1e-3), g1, g2)


def test_memory_config_backend_field_is_static():
    cfg = MemoryConfig(backend="pallas-interpret")
    assert dataclasses.asdict(cfg)["backend"] == "pallas-interpret"
    hash(cfg)   # frozen + hashable, safe as a static jit argument
