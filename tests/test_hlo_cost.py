"""Validation of the while-loop-aware HLO cost model: scanned loops must
cost trip_count × the body, matching the unrolled reference that XLA's
built-in cost_analysis gets right; plus the structural backend_config
parse, the conditional max-branch rule, the all-to-all /
collective-permute byte models, and the alias/parameter helpers the
donation contract builds on."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import (analyze, collective_groups,
                                   entry_parameter_bytes,
                                   input_output_aliases,
                                   parse_backend_config,
                                   trip_count_from_config)


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    w = jnp.ones((128, 128))

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    def unrolled(x):
        for _ in range(10):
            x = x @ w
        return x

    x = jnp.ones((128, 128))
    c_scan = analyze(_hlo(scanned, x))
    c_unroll = analyze(_hlo(unrolled, x))
    base = 2 * 128 ** 3
    assert c_unroll.flops == pytest.approx(10 * base, rel=0.01)
    assert c_scan.flops == pytest.approx(10 * base, rel=0.15)


def test_xla_builtin_undercounts_scan():
    """Documents the undercount this module exists to fix."""
    w = jnp.ones((128, 128))

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.ones((128, 128))
    ca = jax.jit(scanned).lower(x).compile().cost_analysis()
    # jax 0.4.x returns one properties dict per partition, as a list.
    builtin = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    ours = analyze(_hlo(scanned, x)).flops
    assert ours > 5 * builtin


def test_nested_scan_multiplies():
    w = jnp.ones((64, 64))

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jnp.ones((64, 64))
    c = analyze(_hlo(nested, x))
    base = 2 * 64 ** 3
    assert c.flops == pytest.approx(12 * base, rel=0.15)


def test_bytes_scale_with_loop():
    def scanned(x):
        def body(c, _):
            return c * 2.0, None
        out, _ = jax.lax.scan(body, x, None, length=16)
        return out

    big = analyze(_hlo(scanned, jnp.ones((1024, 1024)))).bytes
    small = analyze(_hlo(scanned, jnp.ones((128, 128)))).bytes
    assert big > 20 * small


# ------------------- structural backend_config parse -----------------------

def test_parse_backend_config_inline_and_quoted():
    inline = ('while((s32[], f32[8]) %tuple), condition=%c, body=%b, '
              'backend_config={"known_trip_count":{"n":"12"},'
              '"other":{"nested":{"x":1}}}')
    quoted = ('while((s32[]) %t), body=%b, '
              'backend_config="{\\"known_trip_count\\":{\\"n\\":\\"9\\"}}"')
    assert trip_count_from_config(inline) == 12
    assert trip_count_from_config(quoted) == 9
    assert parse_backend_config(inline)["other"]["nested"]["x"] == 1
    # Absent / unparseable configs fall back to None, never raise.
    assert parse_backend_config("while(%t), body=%b") is None
    assert trip_count_from_config('backend_config="not json"') is None
    assert trip_count_from_config('backend_config={"no_trips":{}}') is None


def test_trip_count_parsed_from_real_scan_config():
    """The structural parse on a genuinely lowered scan: XLA stamps the
    while op with known_trip_count, and the parser must recover exactly
    the scan length from that attribute (not from punctuation luck)."""
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    text = _hlo(f, jnp.eye(16))
    while_lines = [ln for ln in text.splitlines() if " while(" in ln]
    assert while_lines, "no while op in compiled scan"
    assert trip_count_from_config(while_lines[0]) == 7


# ----------------------- conditional max-branch ----------------------------

def test_conditional_costs_max_branch():
    """`conditional` recurses into the heaviest branch: a switch between a
    cheap scale and three chained matmuls must cost ~the matmul branch.
    (The chain is deliberately CSE-proof: ``(x@x)@(x@x)`` would dedupe to
    two dots.)"""
    def f(i, x):
        return jax.lax.switch(
            i, [lambda x: x * 2.0, lambda x: ((x @ x) @ x) @ x], x)

    c = analyze(_hlo(f, jnp.int32(0), jnp.eye(64)))
    base = 2 * 64 ** 3
    assert c.flops == pytest.approx(3 * base, rel=0.15)


# ------------------- collective byte / moved models ------------------------

_COLL_HLO = """\
HloModule m

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %a2a = f32[64,64]{1,0} all-to-all(f32[64,64]{1,0} %p0), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[64,64]{1,0} collective-permute(f32[64,64]{1,0} %a2a), source_target_pairs={{0,1},{1,2}}
}
"""


def test_all_to_all_and_permute_byte_models():
    nbytes = 64 * 64 * 4
    c = analyze(_COLL_HLO)
    assert c.coll["all-to-all"]["count"] == 1
    assert c.coll["all-to-all"]["bytes"] == nbytes
    # all-to-all keeps 1/n resident: (n-1)/n of the payload moves.
    assert c.coll["all-to-all"]["moved"] == pytest.approx(nbytes * 3 / 4)
    # collective-permute is a point-to-point shift: the payload moves once.
    assert c.coll["collective-permute"]["count"] == 1
    assert c.coll["collective-permute"]["moved"] == pytest.approx(nbytes)
    groups = collective_groups(_COLL_HLO)
    by_kind = {g["kind"]: g for g in groups}
    assert by_kind["all-to-all"]["group_size"] == 4
    # No replica_groups attribute parses to None ("possibly global").
    assert by_kind["collective-permute"]["group_size"] is None


# ------------------- alias / entry-parameter helpers -----------------------

def test_aliases_and_param_bytes_on_donated_fn():
    def f(state, x):
        return state + x, x.sum()

    state = jnp.ones((256, 64))
    x = jnp.ones((256, 64))
    donated = jax.jit(f, donate_argnums=(0,)).lower(state, x)
    text = donated.compile().as_text()
    aliased = input_output_aliases(text)
    sizes = entry_parameter_bytes(text)
    assert 0 in aliased, (aliased, text.split("\n", 1)[0])
    assert sizes[0] == 256 * 64 * 4
    assert sizes[1] == 256 * 64 * 4
    # Without donation the alias entry disappears — the donation lint's
    # failure signal.
    plain = jax.jit(f).lower(state, x).compile().as_text()
    assert input_output_aliases(plain) == []
