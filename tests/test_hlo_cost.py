"""Validation of the while-loop-aware HLO cost model: scanned loops must
cost trip_count × the body, matching the unrolled reference that XLA's
built-in cost_analysis gets right."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    w = jnp.ones((128, 128))

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    def unrolled(x):
        for _ in range(10):
            x = x @ w
        return x

    x = jnp.ones((128, 128))
    c_scan = analyze(_hlo(scanned, x))
    c_unroll = analyze(_hlo(unrolled, x))
    base = 2 * 128 ** 3
    assert c_unroll.flops == pytest.approx(10 * base, rel=0.01)
    assert c_scan.flops == pytest.approx(10 * base, rel=0.15)


def test_xla_builtin_undercounts_scan():
    """Documents the undercount this module exists to fix."""
    w = jnp.ones((128, 128))

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jnp.ones((128, 128))
    builtin = jax.jit(scanned).lower(x).compile().cost_analysis()["flops"]
    ours = analyze(_hlo(scanned, x)).flops
    assert ours > 5 * builtin


def test_nested_scan_multiplies():
    w = jnp.ones((64, 64))

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jnp.ones((64, 64))
    c = analyze(_hlo(nested, x))
    base = 2 * 64 ** 3
    assert c.flops == pytest.approx(12 * base, rel=0.15)


def test_bytes_scale_with_loop():
    def scanned(x):
        def body(c, _):
            return c * 2.0, None
        out, _ = jax.lax.scan(body, x, None, length=16)
        return out

    big = analyze(_hlo(scanned, jnp.ones((1024, 1024)))).bytes
    small = analyze(_hlo(scanned, jnp.ones((128, 128)))).bytes
    assert big > 20 * small
