"""End-to-end behaviour tests: SAM learns the paper's tasks, the LM training
driver runs with checkpoint/resume, and the serving driver generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.training import ModelSpec, train_task
from repro.core.types import ControllerConfig, MemoryConfig


MEM = MemoryConfig(num_slots=32, word_size=16, num_heads=2, k=4)
CTL = ControllerConfig(input_size=10, hidden_size=64, output_size=8)


def test_sam_learns_copy():
    """Loss on the copy task must clearly decrease (paper Fig. 2 behaviour,
    CPU-scale)."""
    _, hist = train_task(ModelSpec("sam", MEM, CTL), "copy", steps=250,
                         batch=16, level=2, max_level=4, lr=1e-3)
    first = np.mean([h["loss"] for h in hist[:25]])
    last = np.mean([h["loss"] for h in hist[-25:]])
    assert last < first * 0.75, (first, last)


def test_sam_ann_runs_same_task():
    _, hist = train_task(ModelSpec("sam_ann", MEM, CTL), "copy", steps=30,
                         batch=4, level=2, max_level=4, lr=1e-3)
    assert np.isfinite([h["loss"] for h in hist]).all()


def test_curriculum_advances():
    from repro.data.curriculum import Curriculum
    cur = Curriculum(start_level=1, threshold=10.0, patience=5, max_level=8)
    _, hist = train_task(ModelSpec("lstm", MEM, CTL), "copy", steps=25,
                         batch=4, level=1, max_level=8, curriculum=cur,
                         lr=1e-3)
    assert cur.level > 1                      # threshold is loose: must move


def test_lm_train_driver_with_checkpoint(tmp_path):
    from repro.launch.train import train
    state, log = train("hymba_1_5b", steps=6, batch=2, seq=64,
                       ckpt_dir=str(tmp_path), ckpt_every=2, log_every=2)
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) is not None
    # resume runs further without error
    state2, _ = train("hymba_1_5b", steps=8, batch=2, seq=64,
                      ckpt_dir=str(tmp_path), ckpt_every=4, log_every=4)


def test_serve_driver_generates():
    from repro.launch.serve import serve
    res = serve("h2o_danube_3_4b", batch=2, prompt_len=4, gen_len=4,
                max_len=16)
    assert res["tokens"].shape == (2, 4)


def test_lm_loss_decreases_quickly(rng_key):
    """A tiny LM on the structured synthetic corpus: loss decreases."""
    from repro.launch.train import train
    state, log = train("starcoder2_7b", steps=30, batch=4, seq=128,
                       lr=2e-3, log_every=1)
    losses = [m["loss"] for _, m in log]
    assert losses[-1] < losses[0], losses
